"""Transformer layers: RoPE, norms, attention variants, MLPs.

Every layer is a pair of functions:
  ``<layer>_spec(cfg)``              -> ParamSpec tree (shapes + logical axes)
  ``<layer>_fwd(p, x, ...)``         -> activations

Attention covers the assigned archs' variants behind one interface:
  * GQA (kv_heads < heads)                          — mistral/phi3/minicpm/…
  * sliding window + logit softcap + query scaling  — gemma2 local layers
  * MLA latent attention (+ absorbed decode)        — deepseek-v3
  * cross attention                                 — seamless-m4t decoder
Prefill uses the Pallas flash kernel (or a chunked-XLA path for dry-run
lowering); decode does masked dense attention against the KV cache.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.models.params import ParamSpec, dense, norm_scale

# attention implementation selector:
#   "pallas"      — flash kernel; CPU tests / TPU production path
#   "xla"         — dense einsum; dry-run baseline lowering (S² scores in HBM)
#   "xla_chunked" — online-softmax scan over K blocks in plain XLA; the
#                   flash *schedule* without Pallas — peak memory is
#                   O(S·block) instead of O(S²) (hillclimb iteration)
# Set by launch/dryrun.py.
ATTN_IMPL = "pallas"


def set_attn_impl(impl: str) -> None:
    global ATTN_IMPL
    if impl not in ("pallas", "xla", "xla_chunked"):
        raise ValueError(impl)
    ATTN_IMPL = impl


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_cos_sin(positions: jax.Array, dim: int, theta: float):
    """positions: (...,) int -> cos/sin (..., dim/2) f32."""
    freqs = jnp.exp(-math.log(theta) *
                    jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (S, D/2) or (B, S, D/2)."""
    if cos.ndim == 2:
        cos = cos[None]
        sin = sin[None]
    cos = cos[:, :, None, :]   # (B, S, 1, D/2)
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Norm
# ---------------------------------------------------------------------------
def rmsnorm_fwd(scale: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    # kernel for big rows; jnp for tiny (smoke) rows
    if x.shape[-1] >= 128 and ATTN_IMPL == "pallas":
        return kops.rmsnorm(x, scale, eps=eps)
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------
def mlp_spec(cfg: ArchConfig, d_ff: int | None = None) -> dict:
    f = d_ff or cfg.d_ff
    return {
        "w_gate": dense(cfg.d_model, f, "embed", "ffn"),
        "w_up": dense(cfg.d_model, f, "embed", "ffn"),
        "w_down": dense(f, cfg.d_model, "ffn", "embed"),
    }


def _act(cfg: ArchConfig, x):
    return jax.nn.gelu(x) if cfg.act == "gelu" else jax.nn.silu(x)


def mlp_fwd(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    h = _act(cfg, x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def cache_update(cache: jax.Array, new: jax.Array, idx, *, axis: int):
    """Write ``new`` into ``cache`` at position ``idx`` along ``axis``.

    Plain dynamic-update-slice. NOTE (§Perf minicpm iters 2a-2c): when the
    seq dim was model-sharded, DUS with a traced index forced per-layer
    cache all-gathers (2×144 MiB/layer); a one-hot masked blend was tried
    and REFUTED (gathers grew to 6.3 GB).  The production serving layout
    therefore shards the cache head_dim instead (SERVE_RULES) — seq stays
    unsharded and this update is fully shard-local.
    """
    return jax.lax.dynamic_update_slice_in_dim(
        cache, new.astype(cache.dtype), idx, axis=axis)


# ---------------------------------------------------------------------------
# Attention (GQA family)
# ---------------------------------------------------------------------------
def attn_spec(cfg: ArchConfig) -> dict:
    hd = cfg.resolved_head_dim
    return {
        "wq": dense(cfg.d_model, cfg.num_heads * hd, "embed", "heads"),
        "wk": dense(cfg.d_model, cfg.num_kv_heads * hd, "embed", "kv_heads"),
        "wv": dense(cfg.d_model, cfg.num_kv_heads * hd, "embed", "kv_heads"),
        "wo": dense(cfg.num_heads * hd, cfg.d_model, "heads", "embed"),
    }


def _attention_xla(q, k, v, *, causal, window, softcap, scale,
                   q_offset: int = 0, kv_len: jax.Array | None = None):
    """Dense masked attention in plain XLA (B,H,Sq,D)x(B,Hkv,Sk,D).

    ``q_offset`` positions queries within the kv sequence (decode);
    ``kv_len`` masks out unwritten cache slots.  Either may also be a (B,)
    array — ragged decode, where every batch row sits at its own position
    (continuous batching with mixed prompt lengths).
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    # keep K/V in their storage dtype (bf16 cache!) and accumulate in f32 —
    # upcasting the cache materializes+gathers a 2x-sized f32 copy per layer
    qg = q.reshape(b, hkv, group, sq, d)
    s = jnp.einsum("bkgqd,bkld->bkgql", qg, k,
                   preferred_element_type=jnp.float32) * scale
    # pin scores to the KV layout (seq-sharded under SERVE_RULES) — without
    # this the partitioner prefers all-gathering f32 copies of K/V per layer
    s = shd.constrain_logical(s, ("batch", "kv_heads", None, None, "seq"))
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    if getattr(q_offset, "ndim", 0) >= 1 or getattr(kv_len, "ndim", 0) >= 1:
        # ragged: per-row offsets/lengths -> a (B, Sq, Sk) mask.  Mask
        # VALUES for any given row match the scalar path at that row's
        # position exactly, so uniform batches stay bit-identical.
        qo = jnp.asarray(q_offset, jnp.int32).reshape(-1)
        qpos = qo[:, None, None] + jnp.arange(sq)[None, :, None]
        kpos = jnp.arange(sk)[None, None, :]
        mask = jnp.ones((b, sq, sk), bool)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        if kv_len is not None:
            kl = jnp.asarray(kv_len, jnp.int32).reshape(-1)
            mask &= kpos < kl[:, None, None]
        s = jnp.where(mask[:, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgql,bkld->bkgqd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o.reshape(b, hq, sq, v.shape[-1]).astype(q.dtype)
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    if kv_len is not None:
        mask = mask & (kpos < kv_len)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgql,bkld->bkgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, hq, sq, v.shape[-1]).astype(q.dtype)


def _attention_xla_chunked(q, k, v, *, causal, window, softcap, scale,
                           block: int = 1024, q_offset=0, kv_len=None):
    """Online-softmax attention: lax.scan over K/V blocks (flash schedule in
    plain XLA).  Peak score memory is (B,H,Sq,block) instead of (B,H,Sq,Sk);
    the whole function recomputes in backward (checkpoint) so no per-block
    residuals are saved.  ``q_offset``/``kv_len`` support the cached-prefill
    case (queries positioned inside a longer KV window)."""
    b, hq, sq, dqk = q.shape
    _, hkv, sk, dv = k.shape[0], k.shape[1], k.shape[2], v.shape[-1]
    group = hq // hkv
    nb = sk // block
    qg = (q.reshape(b, hkv, group, sq, dqk) * scale).astype(jnp.float32)
    kb = k.reshape(b, hkv, nb, block, dqk).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nb, block, dv).transpose(2, 0, 1, 3, 4)
    qpos = q_offset + jnp.arange(sq)[:, None]

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        ib, k_blk, v_blk = inp
        s = jnp.einsum("bkgqd,bkld->bkgql", qg, k_blk.astype(jnp.float32))
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        kpos = ib * block + jnp.arange(block)[None, :]
        mask = jnp.ones((sq, block), bool)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        if kv_len is not None:
            mask = mask & (kpos < kv_len)
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha[..., 0][..., None] + jnp.einsum(
            "bkgql,bkld->bkgqd", p, v_blk.astype(jnp.float32))
        return (m_new, l_new, acc), None

    init = (jnp.full((b, hkv, group, sq, 1), -1e30, jnp.float32),
            jnp.zeros((b, hkv, group, sq, 1), jnp.float32),
            jnp.zeros((b, hkv, group, sq, dv), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        step, init, (jnp.arange(nb), kb, vb))
    o = acc / jnp.where(l == 0.0, 1.0, l)
    return o.reshape(b, hq, sq, dv).astype(q.dtype)


def multihead_attention(q, k, v, *, causal=True, window=None, softcap=None,
                        scale=None):
    """Full-sequence attention dispatcher (train/prefill)."""
    d = q.shape[-1]
    scale = (d ** -0.5) if scale is None else scale
    use_pallas = (ATTN_IMPL == "pallas"
                  and q.shape[2] % 128 == 0 and k.shape[2] % 128 == 0
                  and q.shape[-1] == v.shape[-1])
    if use_pallas:
        return kops.attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, scale=scale)
    if ATTN_IMPL == "xla_chunked" and k.shape[2] % 1024 == 0:
        fn = jax.checkpoint(
            functools.partial(_attention_xla_chunked, causal=causal,
                              window=window, softcap=softcap, scale=scale),
            prevent_cse=False)
        return fn(q, k, v)
    return _attention_xla(q, k, v, causal=causal, window=window,
                          softcap=softcap, scale=scale)


def attn_fwd(p: dict, x: jax.Array, cfg: ArchConfig, *, kind: str,
             positions: jax.Array, cache: dict | None = None,
             x_kv: jax.Array | None = None) -> tuple[jax.Array, dict | None]:
    """Unified attention forward.

    x: (B, S, D). kind: dense|local|global|shared_attn|enc|cross.
    cache: None (train/prefill without cache) or
      {"k": (B, Hkv, Smax, hd), "v": ..., "index": scalar} for decode.
    x_kv: encoder output for cross attention.
    Returns (out, updated_cache).
    """
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    src = x if x_kv is None else x_kv
    s_kv = src.shape[1]

    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k = (src @ p["wk"]).reshape(b, s_kv, hkv, hd)
    v = (src @ p["wv"]).reshape(b, s_kv, hkv, hd)

    is_cross = (x_kv is not None) or kind == "cross"
    causal = kind != "enc" and not is_cross
    window = cfg.sliding_window if kind == "local" else None
    if cfg.query_pre_attn_scalar is not None:
        scale = cfg.query_pre_attn_scalar ** -0.5
    else:
        scale = hd ** -0.5

    if not is_cross:  # RoPE on self-attention only
        cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    # shard attention activations by (batch, heads) so the S×S score tensors
    # partition over the model axis instead of replicating
    qt = shd.constrain_logical(q.transpose(0, 2, 1, 3),
                               ("batch", "heads", None, None))
    kt = shd.constrain_logical(k.transpose(0, 2, 1, 3),
                               ("batch", "kv_heads", None, None))
    vt = shd.constrain_logical(v.transpose(0, 2, 1, 3),
                               ("batch", "kv_heads", None, None))

    if cache is None:
        o = multihead_attention(qt, kt, vt, causal=causal, window=window,
                                softcap=cfg.attn_softcap, scale=scale)
        new_cache = None
    else:
        idx = cache["index"]
        if is_cross:
            # cross-attn cache is precomputed once at prefill; mask empty slots
            kt, vt = cache["k"], cache["v"]
            o = _attention_xla(qt, kt, vt, causal=False, window=None,
                               softcap=cfg.attn_softcap, scale=scale,
                               kv_len=idx)
            new_cache = cache
        elif getattr(positions, "ndim", 0) >= 2:
            # ragged decode (s == 1): every batch row writes its KV entry at
            # its OWN position and attends against its own filled extent.
            # One-hot jnp.where writes (pure value copies, batch/head-local;
            # seq stays unsharded under SERVE_RULES so this is shard-local)
            # instead of a shared dynamic_update_slice — the scalar cache
            # "index" keeps ticking but the mask below never reads it.
            pos_b = positions[:, 0].astype(jnp.int32)              # (B,)
            sel = jnp.arange(cache["k"].shape[2])[None, :] == pos_b[:, None]
            ck = jnp.where(sel[:, None, :, None],
                           kt.astype(cache["k"].dtype), cache["k"])
            cv = jnp.where(sel[:, None, :, None],
                           vt.astype(cache["v"].dtype), cache["v"])
            ck = shd.constrain_logical(ck, ("batch", "kv_heads", "seq", None))
            cv = shd.constrain_logical(cv, ("batch", "kv_heads", "seq", None))
            o = _attention_xla(qt, ck, cv, causal=True, window=window,
                               softcap=cfg.attn_softcap, scale=scale,
                               q_offset=pos_b, kv_len=pos_b + s)
            new_cache = {"k": ck, "v": cv, "index": idx + s}
        else:
            ck = cache_update(cache["k"], kt, idx, axis=2)
            cv = cache_update(cache["v"], vt, idx, axis=2)
            ck = shd.constrain_logical(ck, ("batch", "kv_heads", "seq", None))
            cv = shd.constrain_logical(cv, ("batch", "kv_heads", "seq", None))
            if s > 1 and ATTN_IMPL == "xla_chunked" and \
                    ck.shape[2] % 1024 == 0:
                # cached prefill: flash schedule, not dense S² scores
                fn = jax.checkpoint(
                    functools.partial(
                        _attention_xla_chunked, causal=True, window=window,
                        softcap=cfg.attn_softcap, scale=scale,
                        q_offset=idx, kv_len=idx + s), prevent_cse=False)
                o = fn(qt, ck, cv)
            else:
                o = _attention_xla(qt, ck, cv, causal=True, window=window,
                                   softcap=cfg.attn_softcap, scale=scale,
                                   q_offset=idx, kv_len=idx + s)
            new_cache = {"k": ck, "v": cv, "index": idx + s}

    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return o @ p["wo"], new_cache


def attn_cache_spec(cfg: ArchConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16) -> dict:
    hd = cfg.resolved_head_dim
    shape = (batch, cfg.num_kv_heads, max_len, hd)
    axes = ("batch", "kv_heads", "seq", "head_dim")
    return {"k": ParamSpec(shape, axes, "zeros", dtype=dtype),
            "v": ParamSpec(shape, axes, "zeros", dtype=dtype),
            "index": ParamSpec((), (), "zeros", dtype=jnp.int32)}


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (deepseek-v3)
# ---------------------------------------------------------------------------
def mla_spec(cfg: ArchConfig) -> dict:
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    vh = cfg.v_head_dim
    return {
        "wq_a": dense(cfg.d_model, cfg.q_lora_rank, "embed", None),
        "q_norm": norm_scale(cfg.q_lora_rank),
        "wq_b": dense(cfg.q_lora_rank, cfg.num_heads * (nope + rope_d),
                      None, "heads"),
        "wkv_a": dense(cfg.d_model, cfg.kv_lora_rank + rope_d, "embed", None),
        "kv_norm": norm_scale(cfg.kv_lora_rank),
        "wkv_b": dense(cfg.kv_lora_rank, cfg.num_heads * (nope + vh),
                       None, "heads"),
        "wo": dense(cfg.num_heads * vh, cfg.d_model, "heads", "embed"),
    }


def mla_fwd(p: dict, x: jax.Array, cfg: ArchConfig, *,
            positions: jax.Array, cache: dict | None = None
            ) -> tuple[jax.Array, dict | None]:
    b, s, _ = x.shape
    h = cfg.num_heads
    nope, rope_d, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    scale = (nope + rope_d) ** -0.5

    q_lat = rmsnorm_fwd(p["q_norm"], x @ p["wq_a"], cfg.norm_eps)
    q = (q_lat @ p["wq_b"]).reshape(b, s, h, nope + rope_d)
    q = shd.constrain_logical(q, ("batch", None, "heads", None))
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    kv_a = x @ p["wkv_a"]                       # (B, S, r + rope_d)
    c_kv = rmsnorm_fwd(p["kv_norm"], kv_a[..., :r], cfg.norm_eps)
    k_rope = kv_a[..., r:].reshape(b, s, 1, rope_d)

    cos, sin = rope_cos_sin(positions, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)

    if cache is None:
        # prefill/train: materialize per-head K/V from the latent
        kv = (c_kv @ p["wkv_b"]).reshape(b, s, h, nope + vh)
        kv = shd.constrain_logical(kv, ("batch", None, "heads", None))
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, h, rope_d))], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = multihead_attention(q_full.transpose(0, 2, 1, 3),
                                k.transpose(0, 2, 1, 3),
                                v.transpose(0, 2, 1, 3),
                                causal=True, scale=scale)
        # pad V head dim? v_head==vh; attention needs q/k same dim, v free —
        # the pallas kernel assumes same d for q/k/v, so use xla when vh != d_qk
        o = o.transpose(0, 2, 1, 3).reshape(b, s, h * vh)
        return o @ p["wo"], None

    # absorbed decode: score via latent cache, never materialize K/V
    idx = cache["index"]
    if getattr(positions, "ndim", 0) >= 2:
        # ragged decode: per-row one-hot latent writes + per-row causal
        # extent (mirrors the ragged branch in attn_fwd)
        pos_b = positions[:, 0].astype(jnp.int32)                   # (B,)
        sel = jnp.arange(cache["c_kv"].shape[1])[None, :] == pos_b[:, None]
        ckv = jnp.where(sel[:, :, None],
                        c_kv.astype(cache["c_kv"].dtype), cache["c_kv"])
        krc = jnp.where(sel[:, :, None],
                        k_rope[:, :, 0].astype(cache["k_rope"].dtype),
                        cache["k_rope"])
        qpos_b = (pos_b[:, None] + jnp.arange(s)[None, :])[:, None, :, None]
    else:
        ckv = cache_update(cache["c_kv"], c_kv, idx, axis=1)        # (B, Smax, r)
        krc = cache_update(cache["k_rope"], k_rope[:, :, 0], idx, axis=1)
        qpos_b = None

    wkv_b = p["wkv_b"].reshape(r, h, nope + vh)
    w_k = wkv_b[..., :nope]                              # (r, h, nope)
    w_v = wkv_b[..., nope:]                              # (r, h, vh)

    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                       w_k.astype(jnp.float32))          # (B, S, h, r)
    scores = (jnp.einsum("bshr,blr->bhsl", q_abs, ckv.astype(jnp.float32)) +
              jnp.einsum("bshd,bld->bhsl", q_rope.astype(jnp.float32),
                         krc.astype(jnp.float32))) * scale
    # causal within the incoming window: query at idx+i sees keys <= idx+i
    kpos = jnp.arange(ckv.shape[1])[None, None, None, :]
    qpos = qpos_b if qpos_b is not None else \
        (idx + jnp.arange(s))[None, None, :, None]
    scores = jnp.where(kpos <= qpos, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhsl,blr->bshr", probs, ckv.astype(jnp.float32))
    o = jnp.einsum("bshr,rhv->bshv", ctx, w_v.astype(jnp.float32))
    o = o.reshape(b, s, h * vh).astype(x.dtype)
    return o @ p["wo"], {"c_kv": ckv, "k_rope": krc, "index": idx + s}


def mla_cache_spec(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> dict:
    return {"c_kv": ParamSpec((batch, max_len, cfg.kv_lora_rank),
                              ("batch", "seq", "head_dim"), "zeros",
                              dtype=dtype),
            "k_rope": ParamSpec((batch, max_len, cfg.qk_rope_head_dim),
                                ("batch", "seq", None), "zeros", dtype=dtype),
            "index": ParamSpec((), (), "zeros", dtype=jnp.int32)}
