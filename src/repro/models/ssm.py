"""Mamba-2 (SSD) block — attention-free sequence mixing.

Follows the Mamba-2 reference structure with SPLIT input projections
(z / x / B / C / dt as separate weights rather than one fused in_proj): the
fused projection's output dim (2·d_inner + 2·n + heads) is generally not
divisible by the 16-way model axis, which would force replication; the split
form shards each piece on its natural axis.  Compute is identical (XLA fuses
the five matmuls back together on the MXU).

Pipeline: projections -> causal depthwise conv on [x|B|C] -> softplus dt ->
SSD scan (Pallas chunk kernel) -> D-skip -> gated RMSNorm -> out projection.
Decode keeps O(1) state: rolling conv window + (h, n, p) SSD state — this is
why mamba2/zamba2 are the archs that run ``long_500k``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.models.params import ParamSpec, dense, norm_scale


def _dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    return d_inner, nheads


def ssm_spec(cfg: ArchConfig) -> dict:
    d_inner, nheads = _dims(cfg)
    n, w = cfg.ssm_state, cfg.ssm_conv_width
    return {
        "w_z": dense(cfg.d_model, d_inner, "embed", "ssm_in"),
        "w_x": dense(cfg.d_model, d_inner, "embed", "ssm_in"),
        "w_b": dense(cfg.d_model, n, "embed", None),
        "w_c": dense(cfg.d_model, n, "embed", None),
        "w_dt": dense(cfg.d_model, nheads, "embed", None),
        "conv_x": ParamSpec((w, d_inner), (None, "ssm_in"), "normal", 0.5),
        "conv_b": ParamSpec((w, n), (None, None), "normal", 0.5),
        "conv_c": ParamSpec((w, n), (None, None), "normal", 0.5),
        "conv_bias_x": ParamSpec((d_inner,), ("ssm_in",), "zeros"),
        "conv_bias_b": ParamSpec((n,), (None,), "zeros"),
        "conv_bias_c": ParamSpec((n,), (None,), "zeros"),
        "a_log": ParamSpec((nheads,), (None,), "ssm_a", dtype=jnp.float32),
        "d_skip": ParamSpec((nheads,), (None,), "ones", dtype=jnp.float32),
        "dt_bias": ParamSpec((nheads,), (None,), "zeros", dtype=jnp.float32),
        "gate_norm": norm_scale(d_inner),
        "out_proj": dense(d_inner, cfg.d_model, "ssm_in", "embed"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv1d. x: (B, S, C), w: (W, C), state: (B, W-1, C)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
        full = jnp.concatenate([pad, x], axis=1)
    else:
        full = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    new_state = full[:, -(width - 1):] if width > 1 else None
    out = sum(w[i].astype(jnp.float32) *
              jax.lax.slice_in_dim(full.astype(jnp.float32), i,
                                   i + x.shape[1], axis=1)
              for i in range(width))
    return (out + b.astype(jnp.float32)).astype(x.dtype), new_state


def ssm_fwd(p: dict, x: jax.Array, cfg: ArchConfig, *,
            cache: dict | None = None) -> tuple[jax.Array, dict | None]:
    """x: (B, S, d_model) -> (same, updated cache)."""
    bsz, s, _ = x.shape
    d_inner, nheads = _dims(cfg)
    n, pdim = cfg.ssm_state, cfg.ssm_head_dim

    z = x @ p["w_z"]
    xs = x @ p["w_x"]
    bmat = x @ p["w_b"]
    cmat = x @ p["w_c"]
    dt_raw = x @ p["w_dt"]

    cs = cache["conv"] if cache is not None else {"x": None, "b": None, "c": None}
    xs, ncx = _causal_conv(xs, p["conv_x"], p["conv_bias_x"], cs["x"])
    bmat, ncb = _causal_conv(bmat, p["conv_b"], p["conv_bias_b"], cs["b"])
    cmat, ncc = _causal_conv(cmat, p["conv_c"], p["conv_bias_c"], cs["c"])
    xs, bmat, cmat = (jax.nn.silu(t) for t in (xs, bmat, cmat))
    new_conv = {"x": ncx, "b": ncb, "c": ncc}

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))     # (B,S,h)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))               # (h,)
    a_full = a[None, None] * dt                                # (B,S,h) <= 0

    xh = xs.reshape(bsz, s, nheads, pdim)
    xh = shd.constrain_logical(xh, ("batch", None, "heads", None))
    x_in = (xh.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    b_full = jnp.broadcast_to(bmat[:, :, None, :], (bsz, s, nheads, n))
    c_full = jnp.broadcast_to(cmat[:, :, None, :], (bsz, s, nheads, n))

    # pad the sequence up to a chunk multiple (padding has a=0, x=0: decay
    # e^0 = 1 passes state through, zero input adds nothing — the final
    # state and the real tokens' outputs are unaffected)
    chunk = min(cfg.ssm_chunk, s)
    pad = (-s) % chunk
    if pad and s > 1:
        def padseq(t):
            return jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x_in, a_full, b_full, c_full = (padseq(t) for t in
                                        (x_in, a_full, b_full, c_full))

    if cache is None:
        y = kops.ssd(x_in, a_full, b_full, c_full, chunk=chunk)
        new_ssm = None
    elif s == 1:
        y, new_ssm = kops.ssd_decode_step(
            x_in[:, 0].astype(jnp.float32), a_full[:, 0],
            b_full[:, 0].astype(jnp.float32), c_full[:, 0].astype(jnp.float32),
            cache["ssm"])
        y = y[:, None].astype(x.dtype)
    else:  # chunked prefill carrying state
        y, new_ssm = kops.ssd_with_state(
            x_in, a_full, b_full, c_full, chunk=chunk,
            initial_state=cache["ssm"])
    if pad and s > 1:
        y = y[:, :s]

    y = y.reshape(bsz, s, nheads, pdim) + \
        p["d_skip"].astype(jnp.float32)[None, None, :, None] * \
        xh.astype(jnp.float32)
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)

    # gated RMSNorm (mamba2): norm(y * silu(z))
    g = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    gf = g.astype(jnp.float32)
    ms = jnp.mean(gf * gf, axis=-1, keepdims=True)
    g = (gf * jax.lax.rsqrt(ms + cfg.norm_eps) *
         p["gate_norm"].astype(jnp.float32)).astype(x.dtype)

    out = g @ p["out_proj"]
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "ssm": new_ssm}
    return out, new_cache


def ssm_cache_spec(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    d_inner, nheads = _dims(cfg)
    w = cfg.ssm_conv_width
    return {
        "conv": {
            "x": ParamSpec((batch, w - 1, d_inner), ("batch", None, "ssm_in"),
                           "zeros", dtype=dtype),
            "b": ParamSpec((batch, w - 1, cfg.ssm_state),
                           ("batch", None, None), "zeros", dtype=dtype),
            "c": ParamSpec((batch, w - 1, cfg.ssm_state),
                           ("batch", None, None), "zeros", dtype=dtype),
        },
        "ssm": ParamSpec((batch, nheads, cfg.ssm_state, cfg.ssm_head_dim),
                         ("batch", None, None, None), "zeros",
                         dtype=jnp.float32),
    }
