"""Unified model: decoder LMs, MoE, SSM/hybrid, enc-dec — one code path.

A model is a sequence of *groups*; each group is ``(unit, repeat)`` from
``ArchConfig.blocks``.  The unit (a tuple of layer kinds) becomes the body of
one ``lax.scan`` over ``repeat`` — so an 88-layer dense model compiles ONE
layer body, and gemma-2's (local, global) alternation compiles exactly two.
``shared_attn`` layers (zamba2) hold their parameters OUTSIDE the scanned
stack — one "bitstream", referenced by all repetitions (paper's operator
reuse).

Remat is applied to the scan body (``cfg.remat``: full | dots | none) — the
main activation-memory knob for the 4k-train shapes.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.configs.base import ArchConfig
from repro.models import params as pm
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (attn_cache_spec, attn_fwd, attn_spec,
                                 mla_cache_spec, mla_fwd, mla_spec, mlp_fwd,
                                 mlp_spec, rmsnorm_fwd)
from repro.models.params import ParamSpec, dense, embedding, norm_scale

ATTN_KINDS = ("dense", "local", "global", "shared_attn", "enc", "dec",
              "mla_dense", "moe", "mla_moe")


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------
def layer_spec(cfg: ArchConfig, kind: str) -> dict:
    d = cfg.d_model
    if kind == "mamba":
        return {"ln1": norm_scale(d), "mixer": ssm_lib.ssm_spec(cfg)}
    s: dict[str, Any] = {"ln1": norm_scale(d)}
    s["attn"] = mla_spec(cfg) if kind.startswith("mla") else attn_spec(cfg)
    if kind == "dec":
        s["ln_cross"] = norm_scale(d)
        s["cross"] = attn_spec(cfg)
    s["ln2"] = norm_scale(d)
    s["ffn"] = (moe_lib.moe_spec(cfg) if kind in ("moe", "mla_moe")
                else mlp_spec(cfg))
    if cfg.post_norms:
        s["post_ln1"] = norm_scale(d)
        s["post_ln2"] = norm_scale(d)
    return s


def group_spec(cfg: ArchConfig, unit: tuple[str, ...], rep: int) -> dict:
    stacked = {}
    shared = {}
    for i, kind in enumerate(unit):
        if kind == "shared_attn":
            if "shared_attn" not in shared:      # one bitstream for the group
                shared["shared_attn"] = layer_spec(cfg, kind)
        else:
            stacked[f"{i}:{kind}"] = layer_spec(cfg, kind)
    out = {"layers": pm.stack_tree(stacked, rep)}
    if shared:
        out["shared"] = shared
    return out


def model_spec(cfg: ArchConfig) -> dict:
    spec: dict[str, Any] = {"embed": embedding(cfg.vocab_size, cfg.d_model)}
    if cfg.frontend is not None:
        spec["frontend_proj"] = dense(cfg.frontend_dim, cfg.d_model,
                                      None, "embed")
    for gi, (unit, rep) in enumerate(cfg.encoder_blocks):
        spec[f"enc{gi}"] = group_spec(cfg, unit, rep)
    if cfg.encoder_blocks:
        spec["enc_norm"] = norm_scale(cfg.d_model)
    for gi, (unit, rep) in enumerate(cfg.blocks):
        spec[f"g{gi}"] = group_spec(cfg, unit, rep)
    spec["final_norm"] = norm_scale(cfg.d_model)
    if not cfg.tie_embeddings:
        spec["lm_head"] = dense(cfg.d_model, cfg.vocab_size, "embed", "vocab")
    if cfg.mtp_depth:
        spec["mtp"] = {"proj": dense(2 * cfg.d_model, cfg.d_model,
                                     "embed", None),
                       "layer": layer_spec(cfg, "dense"),
                       "norm": norm_scale(cfg.d_model)}
    return spec


# ---------------------------------------------------------------------------
# Cache specs (decode)
# ---------------------------------------------------------------------------
def layer_cache_spec(cfg: ArchConfig, kind: str, batch: int, max_len: int):
    if kind == "mamba":
        return ssm_lib.ssm_cache_spec(cfg, batch)
    if kind.startswith("mla"):
        return mla_cache_spec(cfg, batch, max_len)
    if kind == "dec":
        hd = cfg.resolved_head_dim
        cross = {"k": ParamSpec((batch, cfg.num_kv_heads, max_len, hd),
                                ("batch", "kv_heads", "seq", None), "zeros",
                                dtype=jnp.bfloat16),
                 "v": ParamSpec((batch, cfg.num_kv_heads, max_len, hd),
                                ("batch", "kv_heads", "seq", None), "zeros",
                                dtype=jnp.bfloat16),
                 "index": ParamSpec((), (), "zeros", dtype=jnp.int32)}
        return {"self": attn_cache_spec(cfg, batch, max_len), "cross": cross}
    return attn_cache_spec(cfg, batch, max_len)


def cache_spec(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    spec = {}
    for gi, (unit, rep) in enumerate(cfg.blocks):
        g = {}
        for i, kind in enumerate(unit):
            key = f"{i}:{kind}" if kind != "shared_attn" else f"{i}:shared_attn"
            g[key] = layer_cache_spec(cfg, kind, batch, max_len)
        spec[f"g{gi}"] = pm.stack_tree(g, rep)
    return spec


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def _maybe_post(cfg, p, key, x):
    return rmsnorm_fwd(p[key], x, cfg.norm_eps) if cfg.post_norms else x


def layer_fwd(p: dict, x: jax.Array, kind: str, cfg: ArchConfig, *,
              positions: jax.Array, cache=None, enc_out=None):
    """One layer. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    rs = cfg.residual_scale
    if kind == "mamba":
        h = rmsnorm_fwd(p["ln1"], x, cfg.norm_eps)
        h, new_cache = ssm_lib.ssm_fwd(p["mixer"], h, cfg, cache=cache)
        return x + rs * h, new_cache, aux

    h = rmsnorm_fwd(p["ln1"], x, cfg.norm_eps)
    if kind.startswith("mla"):
        h, self_cache = mla_fwd(p["attn"], h, cfg, positions=positions,
                                cache=cache if kind != "dec" else None)
    else:
        self_c = cache["self"] if (kind == "dec" and cache is not None) else cache
        h, self_cache = attn_fwd(p["attn"], h, cfg, kind=kind,
                                 positions=positions, cache=self_c)
    h = _maybe_post(cfg, p, "post_ln1", h)
    x = x + rs * h

    new_cache = self_cache
    if kind == "dec":
        hc = rmsnorm_fwd(p["ln_cross"], x, cfg.norm_eps)
        cross_c = cache["cross"] if cache is not None else None
        if cross_c is not None:
            hc, _ = attn_fwd(p["cross"], hc, cfg, kind="cross",
                             positions=positions, cache=cross_c)
        else:
            hc, _ = attn_fwd(p["cross"], hc, cfg, kind="cross",
                             positions=positions, x_kv=enc_out)
        x = x + rs * hc
        if cache is not None:
            new_cache = {"self": self_cache, "cross": cross_c}

    h = rmsnorm_fwd(p["ln2"], x, cfg.norm_eps)
    if kind in ("moe", "mla_moe"):
        b, s, d = h.shape
        y, aux = moe_lib.moe_fwd(p["ffn"], h.reshape(b * s, d), cfg)
        h = y.reshape(b, s, d)
    else:
        h = mlp_fwd(p["ffn"], h, cfg)
    h = _maybe_post(cfg, p, "post_ln2", h)
    return x + rs * h, new_cache, aux


def group_fwd(gp: dict, x: jax.Array, unit: tuple[str, ...], rep: int,
              cfg: ArchConfig, *, positions, caches=None, enc_out=None):
    """Scan ``rep`` repetitions of ``unit``. Returns (x, new_caches, aux)."""
    shared = gp.get("shared", {})

    def body(x, xs):
        layer_p, cache_sl = xs
        aux_total = jnp.zeros((), jnp.float32)
        new_cache_sl = {} if cache_sl is not None else None
        for i, kind in enumerate(unit):
            key = f"{i}:{kind}"
            p = shared["shared_attn"] if kind == "shared_attn" else layer_p[key]
            c = cache_sl[key] if cache_sl is not None else None
            x, nc, aux = layer_fwd(p, x, kind, cfg, positions=positions,
                                   cache=c, enc_out=enc_out)
            if new_cache_sl is not None:
                new_cache_sl[key] = nc
            aux_total += aux
        return x, (new_cache_sl, aux_total)

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    if not cfg.scan_layers:
        new_caches, auxs = [], []
        for r in range(rep):
            lp = jax.tree.map(lambda a: a[r], gp["layers"])
            cs = (jax.tree.map(lambda a: a[r], caches)
                  if caches is not None else None)
            x, (nc, aux) = body(x, (lp, cs))
            new_caches.append(nc)
            auxs.append(aux)
        nc_stack = (jax.tree.map(lambda *a: jnp.stack(a), *new_caches)
                    if caches is not None else None)
        return x, nc_stack, jnp.sum(jnp.stack(auxs))

    x, (new_caches, auxs) = jax.lax.scan(body, x, (gp["layers"], caches))
    return x, new_caches, jnp.sum(auxs)


def embed_tokens(params: dict, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    h = params["embed"][tokens] * cfg.embed_scale
    h = h.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    return shd.constrain_logical(h, ("batch", None, None))


def unembed(params: dict, h: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32),
                            params["embed"].astype(jnp.float32))
    else:
        logits = h.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return shd.constrain_logical(logits, ("batch", None, "vocab"))


def encode(params: dict, cfg: ArchConfig, enc_in: jax.Array) -> jax.Array:
    """Encoder stack. enc_in: (B, S, frontend_dim) embeds or (B, S) tokens."""
    if enc_in.ndim == 3:
        h = (enc_in.astype(jnp.bfloat16) @ params["frontend_proj"])
    else:
        h = embed_tokens(params, enc_in, cfg)
    positions = jnp.arange(h.shape[1])
    for gi, (unit, rep) in enumerate(cfg.encoder_blocks):
        h, _, _ = group_fwd(params[f"enc{gi}"], h, unit, rep, cfg,
                            positions=positions)
    return rmsnorm_fwd(params["enc_norm"], h, cfg.norm_eps)


def forward(params: dict, cfg: ArchConfig, tokens: jax.Array, *,
            pos0: jax.Array | int = 0, caches: dict | None = None,
            enc_out: jax.Array | None = None,
            patch_embeds: jax.Array | None = None):
    """Decoder stack. Returns (hidden, new_caches, aux_loss)."""
    h = embed_tokens(params, tokens, cfg)
    if patch_embeds is not None:     # vlm stub: patches replace leading slots
        pe = (patch_embeds.astype(h.dtype) @ params["frontend_proj"])
        npatch = pe.shape[1]
        h = jnp.concatenate([pe, h[:, npatch:]], axis=1)
    if getattr(pos0, "ndim", 0) >= 1:
        # per-row start positions (B,) -> ragged (B, S) position grid; the
        # attention layers switch to per-row cache writes/masks on seeing it
        positions = pos0[:, None] + jnp.arange(tokens.shape[1])[None, :]
    else:
        positions = pos0 + jnp.arange(tokens.shape[1])

    aux_total = jnp.zeros((), jnp.float32)
    new_caches: dict | None = {} if caches is not None else None
    for gi, (unit, rep) in enumerate(cfg.blocks):
        c = caches[f"g{gi}"] if caches is not None else None
        h, nc, aux = group_fwd(params[f"g{gi}"], h, unit, rep, cfg,
                               positions=positions, caches=c, enc_out=enc_out)
        h = shd.constrain_logical(h, ("batch", None, None))
        if new_caches is not None:
            new_caches[f"g{gi}"] = nc
        aux_total += aux
    h = rmsnorm_fwd(params["final_norm"], h, cfg.norm_eps)
    return h, new_caches, aux_total
