"""Model substrate: configurable transformer/SSM/MoE families.

Everything is functional: parameters are nested dicts of arrays, built from a
``ParamSpec`` tree (``params.py``) that carries logical sharding axes, so the
same model code serves CPU smoke tests and the 512-chip dry-run.
"""
