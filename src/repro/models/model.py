"""Top-level model API: loss, train-step pieces, prefill/decode.

Also the **overlay integration**: ``build_step_graph`` registers the model's
stages (embed, each layer group, head) as operators in the overlay library
and returns a DFG — the runtime interpreter assembles the executable step
exactly the way the paper assembles accelerators from bitstreams
(``examples/overlay_assembly.py`` and the fig-3 benchmark drive this path).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import params as pm
from repro.models import transformer as tfm
from repro.models.transformer import cache_spec, model_spec


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None):
    """Mean next-token CE in f32 + accuracy. logits: (B,S,V), labels: (B,S).

    The gold-logit extraction uses a one-hot reduction rather than
    ``take_along_axis``: a gather over a model-sharded vocab axis forces the
    SPMD partitioner to all-gather the full logits; the one-hot einsum
    reduces locally and psums a (B, S) scalar field instead.
    """
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, v, dtype=jnp.float32)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / denom
    return loss, acc


def loss_fn(params: dict, batch: dict, cfg: ArchConfig, *,
            aux_weight: float = 0.01):
    """Returns (loss, metrics). batch keys per family:
       lm:   tokens, labels            (labels = tokens shifted by caller)
       vlm:  + patch_embeds            (patch positions masked from loss)
       audio enc-dec: frames (B,S,F), tokens, labels
    """
    enc_out = None
    if cfg.is_encdec:
        enc_out = tfm.encode(params, cfg, batch["frames"])
    h, _, aux = tfm.forward(
        params, cfg, batch["tokens"], enc_out=enc_out,
        patch_embeds=batch.get("patch_embeds"))
    logits = tfm.unembed(params, h, cfg)

    mask = batch.get("mask")
    if mask is None and "patch_embeds" in batch:
        npatch = batch["patch_embeds"].shape[1]
        pos = jnp.arange(batch["tokens"].shape[1])[None]
        mask = (pos >= npatch).astype(jnp.float32) * \
            jnp.ones_like(batch["labels"], jnp.float32)
    ce, acc = cross_entropy(logits, batch["labels"], mask)

    loss = ce + aux_weight * aux
    if cfg.mtp_depth:
        # deepseek-v3 multi-token prediction (depth 1): one extra layer sees
        # [h_t ; emb(label_t)] and predicts label_{t+1} (i.e. token t+2).
        mtp = params["mtp"]
        lbl_emb = tfm.embed_tokens(params, batch["labels"], cfg)
        h_in = jnp.concatenate([h[:, :-1], lbl_emb[:, :-1]], axis=-1).astype(
            lbl_emb.dtype) @ mtp["proj"]
        h2, _, _ = tfm.layer_fwd(mtp["layer"], h_in, "dense", cfg,
                                 positions=jnp.arange(h_in.shape[1]))
        h2 = tfm.rmsnorm_fwd(mtp["norm"], h2, cfg.norm_eps)
        logits2 = tfm.unembed(params, h2, cfg)
        ce2, _ = cross_entropy(logits2, batch["labels"][:, 1:], None)
        loss = loss + 0.3 * ce2
    return loss, {"ce": ce, "acc": acc, "aux": aux}


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    return pm.init(cache_spec(cfg, batch, max_len), jax.random.PRNGKey(0))


def prefill(params: dict, cfg: ArchConfig, tokens: jax.Array, caches: dict,
            *, enc_in: jax.Array | None = None,
            patch_embeds: jax.Array | None = None):
    """Run the prompt through the decoder, filling caches.

    Returns (logits_last (B, V), caches). For enc-dec models, also runs the
    encoder and fills cross-attn caches.
    """
    enc_out = None
    if cfg.is_encdec:
        enc_out = tfm.encode(params, cfg, enc_in)
        caches = _fill_cross_caches(params, cfg, enc_out, caches)
    h, caches, _ = tfm.forward(params, cfg, tokens, pos0=0, caches=caches,
                               enc_out=enc_out, patch_embeds=patch_embeds)
    logits = tfm.unembed(params, h[:, -1:], cfg)
    return logits[:, 0], caches


def _fill_cross_caches(params, cfg, enc_out, caches):
    """Precompute cross-attention K/V from encoder output (once)."""
    hd = cfg.resolved_head_dim
    b, s, _ = enc_out.shape
    new = dict(caches)
    for gi, (unit, rep) in enumerate(cfg.blocks):
        if "dec" not in unit:
            continue
        g = dict(caches[f"g{gi}"])
        for i, kind in enumerate(unit):
            if kind != "dec":
                continue
            key = f"{i}:{kind}"
            def per_layer(lp):
                k = (enc_out @ lp["cross"]["wk"]).reshape(
                    b, s, cfg.num_kv_heads, hd).transpose(0, 2, 1, 3)
                v = (enc_out @ lp["cross"]["wv"]).reshape(
                    b, s, cfg.num_kv_heads, hd).transpose(0, 2, 1, 3)
                return k, v
            ks, vs = jax.vmap(per_layer)(params[f"g{gi}"]["layers"][key])
            entry = dict(g[key])
            cross = dict(entry["cross"])
            # stacked cache dims: (rep, B, Hkv, Smax, hd); seq axis = 3
            cross["k"] = jax.lax.dynamic_update_slice_in_dim(
                jnp.zeros_like(cross["k"]), ks.astype(cross["k"].dtype),
                0, axis=3)
            cross["v"] = jax.lax.dynamic_update_slice_in_dim(
                jnp.zeros_like(cross["v"]), vs.astype(cross["v"].dtype),
                0, axis=3)
            cross["index"] = jnp.full((rep,), s, jnp.int32)
            entry["cross"] = cross
            g[key] = entry
        new[f"g{gi}"] = g
    return new


def decode_step(params: dict, cfg: ArchConfig, token: jax.Array, caches: dict,
                *, positions: jax.Array | None = None):
    """One token for every sequence in the batch. token: (B, 1).

    ``positions=None`` reads the shared scalar cache index (uniform batch —
    every row at the same depth).  Pass a (B,) int32 array to decode each
    row at its OWN KV position instead: ragged continuous batching, where
    co-resident slots hold prompts of different lengths (serving engine).
    """
    pos0 = _current_index(cfg, caches) if positions is None else positions
    h, caches, _ = tfm.forward(params, cfg, token, pos0=pos0, caches=caches)
    return tfm.unembed(params, h, cfg)[:, 0], caches


def prefill_chunk(params: dict, cfg: ArchConfig, tokens: jax.Array,
                  caches: dict, last_index: jax.Array):
    """Prefill ONE fixed-size chunk of a prompt into ``caches``.

    ``tokens``: (B, C) — the next C prompt tokens, starting at the cache's
    current index.  The final chunk of a prompt may be right-padded to a
    power-of-two bucket; padded positions write garbage K/V beyond the real
    prompt, which is causally masked here and overwritten position-by-
    position by decode before any query can attend to it.  ``last_index``
    is a *traced* int32 scalar selecting the in-chunk position whose
    logits are returned — the chunk length C is the only static shape, so
    one compiled signature serves every prompt sharing a bucket size.
    Returns (logits (B, V), caches).
    """
    pos0 = _current_index(cfg, caches)
    h, caches, _ = tfm.forward(params, cfg, tokens, pos0=pos0, caches=caches)
    logits = tfm.unembed(params, h, cfg)
    sel = jax.lax.dynamic_slice_in_dim(logits, last_index, 1, axis=1)
    return sel[:, 0], caches


def _current_index(cfg: ArchConfig, caches: dict):
    """Fish the scalar decode position out of the (stacked) cache tree."""
    for gi, (unit, rep) in enumerate(cfg.blocks):
        g = caches[f"g{gi}"]
        for i, kind in enumerate(unit):
            entry = g[f"{i}:{kind}"]
            if kind == "mamba":
                continue
            if kind == "dec":
                entry = entry["self"]
            if "index" in entry:
                return entry["index"][0]   # stacked (rep,) — all equal
    return jnp.zeros((), jnp.int32)


# ---------------------------------------------------------------------------
# Overlay integration: the model step as an assembled DFG
# ---------------------------------------------------------------------------
def build_step_graph(cfg: ArchConfig, batch_shape: tuple[int, int]):
    """Register model stages as overlay operators; return the step Graph.

    Stages: embed -> g0 -> g1 ... -> head.  Each stage is a LARGE operator
    taking (params, x); the params input node fans out to every stage (the
    controller's LD_CONST of per-tile configuration).
    """
    from repro.core.graph import Graph
    from repro.core.patterns import Operator, TileClass

    b, s = batch_shape
    spec = model_spec(cfg)
    abstract_params = pm.abstract(spec)

    g = Graph(f"{cfg.name}.fwd")
    p_in = g.input_tree("params", abstract_params)
    tok = g.input("tokens", (b, s), jnp.int32)

    embed_op = Operator(f"{cfg.name}/embed", 2,
                        lambda p, t: tfm.embed_tokens(p, t, cfg),
                        TileClass.LARGE)
    h = g.apply(embed_op, p_in, tok)

    positions = jnp.arange(s)
    for gi, (unit, rep) in enumerate(cfg.blocks):
        def stage_fn(p, x, _gi=gi, _unit=unit, _rep=rep):
            y, _, _ = tfm.group_fwd(p[f"g{_gi}"], x, _unit, _rep, cfg,
                                    positions=positions)
            return y
        op = Operator(f"{cfg.name}/g{gi}", 2, stage_fn, TileClass.LARGE)
        h = g.apply(op, p_in, h)

    head_op = Operator(
        f"{cfg.name}/head", 2,
        lambda p, x: tfm.unembed(p, tfm.rmsnorm_fwd(
            p["final_norm"], x, cfg.norm_eps), cfg),
        TileClass.LARGE)
    out = g.apply(head_op, p_in, h)
    g.output(out)
    return g
